//! Flat model-parameter vector: the unit the P2P layer broadcasts, the
//! aggregate artifact averages, and the quantity the Client-Confident
//! Convergence test measures distances on.

use crate::util::codec::{Reader, Writer};
use anyhow::Result;

/// A model as one flat `f32` vector (layer layout defined by the L2 config;
/// the rust side never needs to know the per-layer shapes).
#[derive(Clone, Debug, PartialEq)]
pub struct ParamVector(pub Vec<f32>);

impl ParamVector {
    pub fn zeros(n: usize) -> Self {
        ParamVector(vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.0
    }

    /// Euclidean distance to another model — the convergence metric of the
    /// paper's CCC check (‖avg_t − avg_{t−1}‖).
    pub fn l2_distance(&self, other: &ParamVector) -> f32 {
        debug_assert_eq!(self.len(), other.len());
        self.0
            .iter()
            .zip(&other.0)
            .map(|(a, b)| {
                let d = a - b;
                (d * d) as f64
            })
            .sum::<f64>()
            .sqrt() as f32
    }

    pub fn l2_norm(&self) -> f32 {
        self.0.iter().map(|a| (a * a) as f64).sum::<f64>().sqrt() as f32
    }

    pub fn is_finite(&self) -> bool {
        self.0.iter().all(|x| x.is_finite())
    }

    /// In-place unweighted mean of several models (CPU fallback used by the
    /// MockTrainer and as a cross-check of the PJRT aggregate artifact).
    pub fn mean_of(models: &[&ParamVector]) -> ParamVector {
        assert!(!models.is_empty());
        let n = models[0].len();
        let mut out = vec![0.0f32; n];
        for m in models {
            debug_assert_eq!(m.len(), n);
            for (o, x) in out.iter_mut().zip(&m.0) {
                *o += x;
            }
        }
        let k = models.len() as f32;
        for o in &mut out {
            *o /= k;
        }
        ParamVector(out)
    }

    pub fn encode(&self, w: &mut Writer) {
        w.f32_slice(&self.0);
    }

    /// Decode into a pooled buffer (`util::pool`) — bit-identical to the
    /// allocating reader; whoever ends the vector's life may recycle it
    /// (dropping it instead is always safe, just a missed reuse).
    pub fn decode(r: &mut Reader) -> Result<Self> {
        Ok(ParamVector(r.f32_vec_pooled()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::forall;

    #[test]
    fn l2_distance_basic() {
        let a = ParamVector(vec![0.0, 3.0]);
        let b = ParamVector(vec![4.0, 0.0]);
        assert!((a.l2_distance(&b) - 5.0).abs() < 1e-6);
        assert_eq!(a.l2_distance(&a), 0.0);
    }

    #[test]
    fn mean_of_identical_is_identity() {
        let a = ParamVector(vec![1.0, -2.0, 3.5]);
        let m = ParamVector::mean_of(&[&a, &a, &a]);
        assert_eq!(m, a);
    }

    #[test]
    fn mean_of_two() {
        let a = ParamVector(vec![1.0, 2.0]);
        let b = ParamVector(vec![3.0, 6.0]);
        assert_eq!(ParamVector::mean_of(&[&a, &b]).0, vec![2.0, 4.0]);
    }

    #[test]
    fn codec_roundtrip_property() {
        forall(
            0xD1F7,
            50,
            |r| {
                let n = r.below(2000);
                ParamVector((0..n).map(|_| r.normal()).collect())
            },
            |pv| {
                let mut w = Writer::new();
                pv.encode(&mut w);
                let bytes = w.into_bytes();
                let got = ParamVector::decode(&mut Reader::new(&bytes))
                    .map_err(|e| e.to_string())?;
                if &got == pv {
                    Ok(())
                } else {
                    Err("roundtrip mismatch".into())
                }
            },
        );
    }

    #[test]
    fn distance_symmetry_property() {
        forall(
            0xD157,
            30,
            |r| {
                let n = 1 + r.below(500);
                let a = ParamVector((0..n).map(|_| r.normal()).collect());
                let b = ParamVector((0..n).map(|_| r.normal()).collect());
                (a, b)
            },
            |(a, b)| {
                let ab = a.l2_distance(b);
                let ba = b.l2_distance(a);
                if (ab - ba).abs() < 1e-4 && ab >= 0.0 {
                    Ok(())
                } else {
                    Err(format!("asymmetric: {ab} vs {ba}"))
                }
            },
        );
    }

    #[test]
    fn mean_within_bounds_property() {
        forall(
            0x3EA7,
            30,
            |r| {
                let n = 1 + r.below(100);
                let k = 1 + r.below(8);
                (0..k)
                    .map(|_| ParamVector((0..n).map(|_| r.normal()).collect()))
                    .collect::<Vec<_>>()
            },
            |models| {
                let refs: Vec<&ParamVector> = models.iter().collect();
                let m = ParamVector::mean_of(&refs);
                for i in 0..m.len() {
                    let lo = models.iter().map(|p| p.0[i]).fold(f32::MAX, f32::min);
                    let hi = models.iter().map(|p| p.0[i]).fold(f32::MIN, f32::max);
                    if m.0[i] < lo - 1e-4 || m.0[i] > hi + 1e-4 {
                        return Err(format!("coord {i} out of hull"));
                    }
                }
                Ok(())
            },
        );
    }
}
