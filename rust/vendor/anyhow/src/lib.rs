//! Offline stub of the `anyhow` crate: the subset of its API that `dfl`
//! uses (`Error`, `Result`, `Context`, `anyhow!`, `bail!`, `ensure!`),
//! reimplemented on `std` only so the workspace builds with no network and
//! no registry.  Swap for the real crates.io `anyhow` by replacing the
//! path dependency — the call sites are source-compatible.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`; that is what permits the blanket
//! `From<E: std::error::Error>` conversion powering `?`.

use std::fmt;

/// A dynamic error: a display chain of messages, innermost cause last.
pub struct Error {
    /// Outermost context first; the root cause is the last entry.
    chain: Vec<String>,
}

impl Error {
    /// Construct from anything printable (the `anyhow!` macro's engine).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a layer of context (used by the [`Context`] impls).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => write!(f, "(empty error)"),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, c) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {c}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures, as in the real `anyhow`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, or from any `Display`
/// value (mirrors the real crate's literal / expression / format arms).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless `$cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::Other, "disk on fire");
        Err(Error::from(e))
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_layers_compose() {
        let e: Error = io_fail().with_context(|| "reading config").unwrap_err();
        assert_eq!(e.root_message(), "reading config");
        assert!(e.to_string().starts_with("reading config: "));
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let got: Result<u32> = None.context("missing key");
        assert_eq!(got.unwrap_err().to_string(), "missing key");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        let msg: &str = "plain expression";
        assert_eq!(anyhow!(msg).to_string(), "plain expression");
        let x = 9;
        assert_eq!(anyhow!("inline {x}").to_string(), "inline 9");
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
    }
}
