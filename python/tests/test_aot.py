"""AOT surface tests: lowering produces parseable HLO text with the right
entry signature, and the on-disk artifacts are in sync with the code."""

import os

import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.config import TINY, CONFIGS

ARTIFACT_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    fns = model.jitted(TINY)
    specs = aot.artifact_specs(TINY)
    fn, args = specs["aggregate"]
    text = aot.to_hlo_text(fn.lower(*args))
    assert "ENTRY" in text and "HloModule" in text
    # f32[K_MAX, P] stack input must appear in the entry computation.
    assert f"f32[{TINY.k_max},{TINY.n_params}]" in text


def test_artifact_specs_cover_full_surface():
    specs = aot.artifact_specs(TINY)
    assert set(specs) == {
        "init",
        "train_step",
        "train_epoch",
        "eval_round",
        "eval_full",
        "aggregate",
    }


@pytest.mark.parametrize("cfg_name", sorted(CONFIGS))
def test_on_disk_artifacts_exist_and_meta_consistent(cfg_name):
    cfg = CONFIGS[cfg_name]
    d = os.path.join(ARTIFACT_ROOT, cfg_name)
    if not os.path.isdir(d):
        pytest.skip("artifacts not built (run `make artifacts`)")
    for name in aot.artifact_specs(cfg):
        path = os.path.join(d, f"{name}.hlo.txt")
        assert os.path.isfile(path), f"missing {path}"
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head
    meta = {}
    with open(os.path.join(d, "meta.txt")) as f:
        for line in f:
            k, v = line.strip().split("=")
            meta[k] = v
    assert int(meta["n_params"]) == cfg.n_params
    assert int(meta["batch"]) == cfg.batch
    assert int(meta["k_max"]) == cfg.k_max


def test_train_epoch_hlo_contains_loop_not_unroll():
    """DESIGN SSPerf (L2): scan must lower to a while loop, keeping the
    artifact O(1) in nb_train rather than O(nb) copies of the step."""
    fns = model.jitted(TINY)
    specs = aot.artifact_specs(TINY)
    fn, args = specs["train_epoch"]
    text = aot.to_hlo_text(fn.lower(*args))
    assert "while" in text, "scan did not lower to a while loop"
