"""L2 model graph tests: shapes, determinism, learning, aggregation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.config import CONFIGS, TINY, FAST, PAPER


def _data(cfg, nb, seed=0):
    k1, k2 = jax.random.split(jax.random.key(seed))
    xs = jax.random.normal(
        k1, (nb, cfg.batch, cfg.img, cfg.img, cfg.channels), jnp.float32
    )
    ys = jax.random.randint(k2, (nb, cfg.batch), 0, cfg.classes, jnp.int32)
    return xs, ys


def test_param_counts():
    # DESIGN.md §7: paper CNN = 219,958 params (paper reports ~225,034).
    assert PAPER.n_params == 219_958
    assert FAST.n_params == 66_358
    assert TINY.n_params == 6_202


def test_layer_layout_is_contiguous():
    for cfg in CONFIGS.values():
        off = 0
        for layer in cfg.layers():
            assert layer.offset == off
            off += layer.size
        assert off == cfg.n_params


def test_init_deterministic_in_seed():
    fns = model.jitted(TINY)
    (a,) = fns["init"](jnp.uint32(7))
    (b,) = fns["init"](jnp.uint32(7))
    (c,) = fns["init"](jnp.uint32(8))
    np.testing.assert_array_equal(a, b)
    assert float(jnp.abs(a - c).max()) > 0


def test_init_bias_zero_weights_scaled():
    (params,) = model.jitted(TINY)["init"](jnp.uint32(0))
    p = model.unflatten(TINY, params)
    np.testing.assert_array_equal(p["conv1_b"], jnp.zeros_like(p["conv1_b"]))
    np.testing.assert_array_equal(p["fc2_b"], jnp.zeros_like(p["fc2_b"]))
    assert float(jnp.std(p["fc1_w"])) > 0


def test_forward_shape_and_finiteness():
    (params,) = model.jitted(TINY)["init"](jnp.uint32(1))
    xs, _ = _data(TINY, 1)
    logits = model.forward(TINY, params, xs[0])
    assert logits.shape == (TINY.batch, TINY.classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_epoch_reduces_loss():
    fns = model.jitted(TINY)
    (params,) = fns["init"](jnp.uint32(2))
    xs, ys = _data(TINY, TINY.nb_train, seed=3)
    losses = []
    for _ in range(6):
        params, loss = fns["train_epoch"](params, xs, ys, jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, f"no learning: {losses}"


def test_train_step_equals_epoch_of_one():
    # train_epoch with nb=1 must equal a single train_step.
    cfg = TINY
    (params,) = model.jitted(cfg)["init"](jnp.uint32(4))
    xs, ys = _data(cfg, 1, seed=5)
    p_step, l_step = model.train_step(cfg, params, xs[0], ys[0], 0.05)
    p_ep, l_ep = model.train_epoch(cfg, params, xs, ys, 0.05)
    np.testing.assert_allclose(p_step, p_ep, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(l_step), float(l_ep), rtol=1e-5)


def test_evaluate_counts_correct():
    cfg = TINY
    (params,) = model.jitted(cfg)["init"](jnp.uint32(6))
    xs, ys = _data(cfg, cfg.nb_eval_round, seed=7)
    correct, loss = model.jitted(cfg)["evaluate"](params, xs, ys)
    total = cfg.nb_eval_round * cfg.batch
    assert 0 <= int(correct) <= total
    assert float(loss) > 0

    # Oracle: recompute argmax outside the scan.
    preds = jnp.stack([
        jnp.argmax(model.forward(cfg, params, xs[i]), -1) for i in range(xs.shape[0])
    ]).astype(jnp.int32)
    assert int(correct) == int((preds == ys).sum())


def test_aggregate_identical_models_fixed_point():
    cfg = TINY
    (params,) = model.jitted(cfg)["init"](jnp.uint32(8))
    stack = jnp.tile(params, (cfg.k_max, 1))
    w = jnp.ones(cfg.k_max).at[3:].set(0.0)  # only 3 peers alive
    (out,) = model.jitted(cfg)["aggregate"](stack, w)
    np.testing.assert_allclose(out, params, rtol=1e-5, atol=1e-6)


def test_aggregate_masks_crashed_peers():
    cfg = TINY
    fns = model.jitted(cfg)
    (a,) = fns["init"](jnp.uint32(9))
    (b,) = fns["init"](jnp.uint32(10))
    stack = jnp.zeros((cfg.k_max, cfg.n_params))
    stack = stack.at[0].set(a).at[1].set(b).at[2].set(1e30)  # row 2 = garbage
    w = jnp.zeros(cfg.k_max).at[0].set(1.0).at[1].set(1.0)
    (out,) = fns["aggregate"](stack, w)
    np.testing.assert_allclose(out, (a + b) / 2, rtol=1e-4, atol=1e-5)


def test_federated_round_improves_over_isolated():
    """Mini 2-client FedAvg sanity: averaging two locally-trained models on
    split data is finite & stays in the convex hull (smoke of the FL loop)."""
    cfg = TINY
    fns = model.jitted(cfg)
    (p0,) = fns["init"](jnp.uint32(11))
    xs, ys = _data(cfg, 2 * cfg.nb_train, seed=12)
    # train_epoch donates its params argument -> pass fresh copies.
    pa, _ = fns["train_epoch"](
        jnp.array(p0, copy=True), xs[: cfg.nb_train], ys[: cfg.nb_train], jnp.float32(0.05)
    )
    pb, _ = fns["train_epoch"](
        jnp.array(p0, copy=True), xs[cfg.nb_train :], ys[cfg.nb_train :], jnp.float32(0.05)
    )
    stack = jnp.zeros((cfg.k_max, cfg.n_params)).at[0].set(pa).at[1].set(pb)
    w = jnp.zeros(cfg.k_max).at[:2].set(1.0)
    (avg,) = fns["aggregate"](stack, w)
    assert bool(jnp.all(jnp.isfinite(avg)))
    np.testing.assert_allclose(avg, (pa + pb) / 2, rtol=1e-4, atol=1e-5)
