"""L1 matmul kernel vs the pure-jnp oracle, including its custom VJP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import matmul
from compile.kernels import ref

DIM = st.integers(min_value=1, max_value=70)


def _rand(key, shape):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32)


@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_shapes(m, k, n, seed):
    x = _rand(seed, (m, k))
    y = _rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),          # degenerate
        (8, 8, 8),          # exact single block
        (128, 128, 128),    # exact MXU block
        (129, 130, 131),    # every dim needs padding
        (256, 64, 16),      # multi-block M, single-block N
        (3, 200, 5),        # K spans multiple blocks
    ],
)
def test_matmul_block_boundaries(m, k, n):
    x = _rand(0, (m, k))
    y = _rand(1, (k, n))
    np.testing.assert_allclose(
        matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-5
    )


def test_matmul_grad_matches_autodiff():
    x = _rand(2, (9, 17))
    y = _rand(3, (17, 6))

    def f_pallas(x, y):
        return jnp.sum(jnp.sin(matmul(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.sin(jnp.matmul(x, y)))

    gx_p, gy_p = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gy_p, gy_r, rtol=1e-4, atol=1e-5)


def test_matmul_under_jit_and_vmap_scan():
    # The kernel must compose with jit (the AOT path wraps everything in jit).
    x = _rand(4, (12, 8))
    y = _rand(5, (8, 12))
    out = jax.jit(matmul)(x, y)
    np.testing.assert_allclose(out, jnp.matmul(x, y), rtol=1e-4, atol=1e-5)


def test_matmul_zero_and_identity():
    x = _rand(6, (10, 10))
    eye = jnp.eye(10)
    np.testing.assert_allclose(matmul(x, eye), x, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        matmul(x, jnp.zeros((10, 4))), jnp.zeros((10, 4)), atol=1e-6
    )
