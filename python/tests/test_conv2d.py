"""conv2d / dense (patches + Pallas matmul) vs lax.conv oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from compile.kernels import conv2d, dense
from compile.kernels import ref


def _rand(key, shape):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32)


@given(
    b=st.integers(1, 4),
    hw=st.sampled_from([4, 8, 12, 16]),
    cin=st.integers(1, 4),
    cout=st.integers(1, 8),
    k=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_lax(b, hw, cin, cout, k, seed):
    x = _rand(seed, (b, hw, hw, cin))
    w = _rand(seed + 1, (k, k, cin, cout)) * 0.2
    bias = _rand(seed + 2, (cout,))
    np.testing.assert_allclose(
        conv2d(x, w, bias), ref.conv2d_ref(x, w, bias), rtol=1e-3, atol=1e-4
    )


def test_conv2d_paper_shapes():
    # The two convs of the paper CNN at 32x32.
    x = _rand(0, (2, 32, 32, 3))
    w1 = _rand(1, (5, 5, 3, 16)) * 0.1
    b1 = jnp.zeros(16)
    out1 = conv2d(x, w1, b1)
    assert out1.shape == (2, 32, 32, 16)
    np.testing.assert_allclose(
        out1, ref.conv2d_ref(x, w1, b1), rtol=1e-3, atol=1e-4
    )


def test_conv2d_gradients_match_lax():
    x = _rand(2, (2, 8, 8, 3))
    w = _rand(3, (3, 3, 3, 4)) * 0.3
    b = _rand(4, (4,))

    def f_pallas(x, w, b):
        return jnp.sum(jax.nn.relu(conv2d(x, w, b)))

    def f_ref(x, w, b):
        return jnp.sum(jax.nn.relu(ref.conv2d_ref(x, w, b)))

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(gp, gr):
        np.testing.assert_allclose(a, r, rtol=1e-3, atol=1e-4)


@given(
    b=st.integers(1, 8),
    din=st.integers(1, 64),
    dout=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(b, din, dout, seed):
    x = _rand(seed, (b, din))
    w = _rand(seed + 1, (din, dout))
    bias = _rand(seed + 2, (dout,))
    np.testing.assert_allclose(
        dense(x, w, bias), ref.dense_ref(x, w, bias), rtol=1e-4, atol=1e-5
    )


def test_conv2d_channel_mismatch_raises():
    x = _rand(0, (1, 8, 8, 3))
    w = _rand(1, (3, 3, 4, 4))  # wrong Cin
    with pytest.raises(AssertionError):
        conv2d(x, w, jnp.zeros(4))
