"""Fused SGD update kernel vs oracle + algebraic invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.kernels import sgd_update
from compile.kernels import ref


def _rand(key, shape):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32)


@given(
    p=st.sampled_from([1, 5, 2048, 2049, 66358, 219958]),
    lr=st.floats(0.0, 1.0, allow_nan=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_matches_ref(p, lr, seed):
    params = _rand(seed, (p,))
    grads = _rand(seed + 1, (p,))
    np.testing.assert_allclose(
        sgd_update(params, grads, lr),
        ref.sgd_update_ref(params, grads, lr),
        rtol=1e-5,
        atol=1e-6,
    )


def test_sgd_zero_lr_is_identity():
    p = _rand(0, (5000,))
    g = _rand(1, (5000,))
    np.testing.assert_allclose(sgd_update(p, g, 0.0), p, atol=0)


def test_sgd_zero_grad_is_identity():
    p = _rand(2, (321,))
    np.testing.assert_allclose(sgd_update(p, jnp.zeros(321), 0.5), p, atol=0)


def test_sgd_linearity_in_lr():
    p = _rand(3, (1000,))
    g = _rand(4, (1000,))
    step1 = np.asarray(p) - np.asarray(sgd_update(p, g, 0.1))
    step2 = np.asarray(p) - np.asarray(sgd_update(p, g, 0.2))
    np.testing.assert_allclose(step2, 2 * step1, rtol=1e-4, atol=1e-6)
