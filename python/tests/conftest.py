"""Shared pytest fixtures/settings for the build-time python suite."""

import os
import sys

# Allow `pytest python/tests` from the repo root as well as `cd python`.
_HERE = os.path.dirname(os.path.abspath(__file__))
_PYROOT = os.path.dirname(_HERE)
if _PYROOT not in sys.path:
    sys.path.insert(0, _PYROOT)

from hypothesis import settings

# Pallas interpret mode is slow; keep sweeps bounded but meaningful.
settings.register_profile("dfl", max_examples=20, deadline=None)
settings.load_profile("dfl")
