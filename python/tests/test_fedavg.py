"""FedAvg aggregation kernel: oracle agreement + masking invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile.kernels import fedavg
from compile.kernels import ref


def _rand(key, shape):
    return jax.random.normal(jax.random.key(key), shape, jnp.float32)


@given(
    k=st.integers(1, 16),
    p=st.sampled_from([1, 7, 1024, 1025, 4000]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fedavg_matches_ref(k, p, seed):
    stack = _rand(seed, (k, p))
    w = jnp.abs(_rand(seed + 1, (k,)))
    np.testing.assert_allclose(
        fedavg(stack, w), ref.fedavg_ref(stack, w), rtol=1e-4, atol=1e-5
    )


def test_fedavg_mask_ignores_garbage_rows():
    # Rows with weight 0 (crashed/absent peers) must not affect the result,
    # even if they contain huge garbage -- the coordinator relies on this.
    stack = _rand(0, (8, 500))
    garbage = stack.at[3].set(1e30).at[6].set(-1e30)
    w = jnp.array([1, 1, 1, 0, 1, 1, 0, 1], jnp.float32)
    np.testing.assert_allclose(
        fedavg(garbage, w), ref.fedavg_ref(stack, w), rtol=1e-5, atol=1e-5
    )


def test_fedavg_single_survivor_is_identity():
    stack = _rand(1, (16, 777))
    w = jnp.zeros(16).at[5].set(3.0)
    np.testing.assert_allclose(fedavg(stack, w), stack[5], rtol=1e-5, atol=1e-6)


def test_fedavg_identical_rows_fixed_point():
    row = _rand(2, (600,))
    stack = jnp.tile(row, (10, 1))
    w = jnp.abs(_rand(3, (10,))) + 0.1
    np.testing.assert_allclose(fedavg(stack, w), row, rtol=1e-5, atol=1e-5)


def test_fedavg_all_zero_weights_is_zero_not_nan():
    stack = _rand(4, (4, 100))
    out = fedavg(stack, jnp.zeros(4))
    assert np.all(np.isfinite(np.asarray(out)))
    np.testing.assert_allclose(out, jnp.zeros(100), atol=1e-6)


def test_fedavg_weight_scale_invariance():
    stack = _rand(5, (6, 333))
    w = jnp.abs(_rand(6, (6,))) + 0.01
    a = fedavg(stack, w)
    b = fedavg(stack, w * 17.0)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_fedavg_convexity_bounds():
    # Output of an average must lie within [min, max] per coordinate.
    stack = _rand(7, (5, 256))
    w = jnp.abs(_rand(8, (5,))) + 0.1
    out = np.asarray(fedavg(stack, w))
    lo, hi = np.min(np.asarray(stack), 0), np.max(np.asarray(stack), 0)
    assert np.all(out >= lo - 1e-5) and np.all(out <= hi + 1e-5)
