"""AOT pipeline: lower every L2 graph to HLO **text** artifacts.

Run once at build time (`make artifacts`); the rust runtime loads the text
with `HloModuleProto::from_text_file`, compiles on the PJRT CPU client and
executes -- python never appears on the request path.

HLO *text* (not a serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Usage: python -m compile.aot [--out-dir ../artifacts] [--configs paper,fast]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .config import CONFIGS, ModelConfig
from . import model


def to_hlo_text(lowered) -> str:
    """jax Lowered -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def artifact_specs(cfg: ModelConfig) -> dict:
    """name -> (callable, example arg specs).  The full AOT surface."""
    p, b, e = cfg.n_params, cfg.batch, cfg.img
    img = (b, e, e, cfg.channels)
    fns = model.jitted(cfg)
    return {
        "init": (fns["init"], [_spec((), jnp.uint32)]),
        "train_step": (
            fns["train_step"],
            [_spec((p,)), _spec(img), _spec((b,), jnp.int32), _spec(())],
        ),
        "train_epoch": (
            fns["train_epoch"],
            [
                _spec((p,)),
                _spec((cfg.nb_train,) + img),
                _spec((cfg.nb_train, b), jnp.int32),
                _spec(()),
            ],
        ),
        "eval_round": (
            fns["evaluate"],
            [
                _spec((p,)),
                _spec((cfg.nb_eval_round,) + img),
                _spec((cfg.nb_eval_round, b), jnp.int32),
            ],
        ),
        "eval_full": (
            fns["evaluate"],
            [
                _spec((p,)),
                _spec((cfg.nb_eval_full,) + img),
                _spec((cfg.nb_eval_full, b), jnp.int32),
            ],
        ),
        "aggregate": (
            fns["aggregate"],
            [_spec((cfg.k_max, p)), _spec((cfg.k_max,))],
        ),
    }


def write_meta(cfg: ModelConfig, out_dir: str) -> None:
    """key=value metadata the rust runtime parses (shapes it must feed)."""
    lines = [
        f"config={cfg.name}",
        f"n_params={cfg.n_params}",
        f"img={cfg.img}",
        f"channels={cfg.channels}",
        f"classes={cfg.classes}",
        f"batch={cfg.batch}",
        f"nb_train={cfg.nb_train}",
        f"nb_eval_round={cfg.nb_eval_round}",
        f"nb_eval_full={cfg.nb_eval_full}",
        f"k_max={cfg.k_max}",
    ]
    with open(os.path.join(out_dir, "meta.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def build_config(cfg: ModelConfig, root: str) -> None:
    out_dir = os.path.join(root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    specs = artifact_specs(cfg)
    for name, (fn, args) in specs.items():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(fn.lower(*args))
        with open(path, "w") as f:
            f.write(text)
        print(f"  {cfg.name}/{name}.hlo.txt  ({len(text)} chars)")
    write_meta(cfg, out_dir)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,fast,paper")
    args = ap.parse_args()
    for name in args.configs.split(","):
        cfg = CONFIGS.get(name.strip())
        if cfg is None:
            sys.exit(f"unknown config {name!r}; have {sorted(CONFIGS)}")
        print(f"[aot] lowering config {cfg.name} (P={cfg.n_params})")
        build_config(cfg, args.out_dir)
    print("[aot] done")


if __name__ == "__main__":
    main()
