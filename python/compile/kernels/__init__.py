"""Layer-1 Pallas kernels (build-time only).

Every kernel here is authored as a TPU-shaped Pallas kernel and executed with
``interpret=True`` so it lowers to plain HLO that the rust PJRT CPU client can
run (real-TPU lowering emits Mosaic custom-calls the CPU plugin cannot
execute; see /opt/xla-example/README.md).

Public surface:
  matmul      -- tiled matmul with custom VJP (both bwd matmuls also Pallas)
  conv2d      -- SAME conv via patch extraction + Pallas matmul
  dense       -- fully-connected layer on the Pallas matmul
  fedavg      -- masked weighted model averaging (the FL aggregation hot spot)
  sgd_update  -- fused axpy parameter update
Correctness oracles live in ``ref.py`` and are enforced by python/tests.
"""

from .matmul import matmul
from .conv2d import conv2d, dense
from .fedavg import fedavg
from .sgd import sgd_update

__all__ = ["matmul", "conv2d", "dense", "fedavg", "sgd_update"]
