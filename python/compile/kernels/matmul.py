"""Tiled Pallas matmul with a custom VJP.

This is the single compute primitive every layer of the model routes through
(conv2d goes patches -> matmul, dense is matmul + bias), so the whole
fwd+bwd graph bottoms out in this kernel -- including the backward pass,
whose two gradient matmuls are themselves Pallas calls.

TPU shaping: blocks are capped at 128x128x128 (MXU systolic tile multiples)
with an output accumulator kept resident in VMEM across the K grid dimension
(`o_ref[...] +=` under sequential K semantics).  On this CPU substrate the
kernel runs under interpret=True; tile caps adapt downward to the actual
(padded) problem so small model layers do not pay 8-16x zero-padding FLOPs.
See DESIGN.md §6 for the VMEM / MXU-utilization estimates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly ceiling for block edges; actual blocks shrink to the padded
# problem dims so tiny layers aren't padded up to 128.
MAX_BLOCK = 128
# Pad every dim to a multiple of this (VPU lane-friendly, keeps index maps
# exact without masking).
ALIGN = 8


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _block(dim: int) -> int:
    return min(MAX_BLOCK, _round_up(dim, ALIGN))


def _matmul_kernel(x_ref, y_ref, o_ref):
    # Zero the VMEM accumulator on the first K step, then accumulate one
    # (bm, bk) @ (bk, bn) product per K step.  f32 accumulation regardless of
    # input dtype (preferred_element_type) -- the MXU-native discipline.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=())
def _matmul_pallas(x: jax.Array, y: jax.Array) -> jax.Array:
    """(M, K) @ (K, N) -> (M, N) via the tiled Pallas kernel."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul inner dims mismatch: {x.shape} @ {y.shape}"
    bm, bk, bn = _block(m), _block(k), _block(n)
    pm, pk, pn = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, pm - m), (0, pk - k))) if (pm, pk) != (m, k) else x
    yp = jnp.pad(y, ((0, pk - k), (0, pn - n))) if (pk, pn) != (k, n) else y

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(pm // bm, pn // bn, pk // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n] if (pm, pn) != (m, n) else out


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pallas matmul; differentiable (both grads are Pallas matmuls too)."""
    return _matmul_pallas(x, y)


def _matmul_fwd(x, y):
    return _matmul_pallas(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # dX = g @ Y^T, dY = X^T @ g -- both through the same Pallas kernel so
    # the AOT-lowered backward pass stays on the L1 path.
    return _matmul_pallas(g, y.T), _matmul_pallas(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
