"""Pure-jnp oracles for every L1 Pallas kernel.

These are the ground truth the pytest suite checks the kernels against
(`assert_allclose`).  They intentionally use a *different* lowering path
(lax.conv_general_dilated, plain jnp reductions) so agreement is meaningful.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.matmul(x, y)


def conv2d_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """stride-1 SAME conv via lax.conv_general_dilated (NHWC / HWIO)."""
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def dense_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.matmul(x, w) + b


def fedavg_ref(stack: jax.Array, weights: jax.Array) -> jax.Array:
    wn = weights / jnp.maximum(weights.sum(), 1e-12)
    return jnp.sum(stack * wn[:, None], axis=0)


def sgd_update_ref(params: jax.Array, grads: jax.Array, lr) -> jax.Array:
    return params - jnp.asarray(lr, jnp.float32) * grads
