"""Masked weighted FedAvg aggregation as a tiled Pallas reduction.

This is the FL aggregation hot spot: given a stack of K_MAX flat model
vectors (rows for absent/crashed peers are garbage) and a weight vector
(0 for absent peers), produce the weighted average model.

TPU shaping: the parameter axis is tiled into (1, BP) VMEM-resident blocks;
each grid step streams a (K_MAX, BP) slab HBM->VMEM and reduces over K on
the VPU.  K_MAX is small (16) so the slab is ~64 KiB at BP=1024 -- well
under VMEM.  Weights are pre-normalized host-side (a K_MAX-length op, not
worth a kernel) so the kernel is a pure weighted sum.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Parameter-axis block. (K_MAX, BP) f32 slab at BP=4096 is ~256 KiB — still
# comfortably VMEM-resident, and 4x fewer grid steps than BP=1024 cuts the
# per-step loop overhead of the interpret-mode lowering (EXPERIMENTS.md
# §Perf: aggregate_8 9.8ms → re-measured after this change).
BP = 4096


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _fedavg_kernel(s_ref, w_ref, o_ref):
    # s_ref: (K, BP) slab, w_ref: (K, 1) normalized weights -> o_ref: (1, BP)
    o_ref[...] = jnp.sum(s_ref[...] * w_ref[...], axis=0, keepdims=True)


def fedavg(stack: jax.Array, weights: jax.Array) -> jax.Array:
    """Weighted average of model rows.

    stack: (K, P) f32, weights: (K,) f32 (>= 0, not necessarily normalized;
    all-zero weights yield the zero model rather than NaN).
    Returns (P,) f32 = sum_k w_k * stack[k] / max(sum_k w_k, eps).
    """
    k, p = stack.shape
    wn = weights / jnp.maximum(weights.sum(), 1e-12)
    bp = min(BP, _round_up(p, 8))
    pp = _round_up(p, bp)
    sp = jnp.pad(stack, ((0, 0), (0, pp - p))) if pp != p else stack

    out = pl.pallas_call(
        _fedavg_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((k, bp), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, pp), jnp.float32),
        interpret=True,
    )(sp, wn.reshape(k, 1))
    return out[0, :p]
