"""Fused SGD parameter update as a 1-D tiled Pallas kernel.

p' = p - lr * g over the flat parameter vector.  A single fused axpy pass:
one HBM read per operand, one write, no intermediate allocation -- the
update the optimizer applies after every local minibatch.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BP = 2048  # block along the (reshaped) parameter axis


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _sgd_kernel(p_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = p_ref[...] - lr_ref[0, 0] * g_ref[...]


def sgd_update(params: jax.Array, grads: jax.Array, lr: jax.Array) -> jax.Array:
    """params, grads: (P,) f32; lr: scalar f32.  Returns params - lr*grads."""
    (p,) = params.shape
    bp = min(BP, _round_up(p, 8))
    pp = _round_up(p, bp)
    pad = pp - p
    pv = jnp.pad(params, (0, pad)) if pad else params
    gv = jnp.pad(grads, (0, pad)) if pad else grads
    # 2-D shaping (rows of BP) keeps the BlockSpec index map trivial.
    pv2 = pv.reshape(pp // bp, bp)
    gv2 = gv.reshape(pp // bp, bp)
    lr2 = jnp.asarray(lr, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _sgd_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((1, bp), lambda i: (i, 0)),
            pl.BlockSpec((1, bp), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pp // bp, bp), jnp.float32),
        interpret=True,
    )(pv2, gv2, lr2)
    return out.reshape(pp)[:p]
