"""SAME-padded 2-D convolution and dense layer on the Pallas matmul.

The conv is expressed as patch extraction (im2col) followed by the L1 tiled
Pallas matmul -- the standard way to feed a convolution to a systolic matmul
unit (MXU).  Patch extraction / fold-back are cheap data movement handled by
XLA; every FLOP-heavy contraction (forward, dW, dX) runs through
``kernels.matmul``'s custom-VJP Pallas kernel.
"""

import jax
import jax.numpy as jnp

from .matmul import matmul


def _extract_patches(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """im2col for stride-1 SAME conv.

    x: (B, H, W, C)  ->  (B*H*W, kh*kw*C), patch center at each pixel.
    Built from static rolls so it lowers to pad+slice HLO (pure data
    movement) and is trivially differentiable (the transpose is col2im).
    """
    b, h, w, c = x.shape
    ph, pw = kh // 2, kw // 2
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    rows = []
    for di in range(kh):
        for dj in range(kw):
            rows.append(xp[:, di : di + h, dj : dj + w, :])
    # (B, H, W, kh*kw, C) -> (B*H*W, kh*kw*C)
    patches = jnp.stack(rows, axis=3)
    return patches.reshape(b * h * w, kh * kw * c)


def conv2d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """stride-1 SAME conv.  x: (B,H,W,Cin), w: (KH,KW,Cin,Cout), b: (Cout,)."""
    bs, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, f"conv channel mismatch {x.shape} vs {w.shape}"
    patches = _extract_patches(x, kh, kw)            # (B*H*W, KH*KW*Cin)
    wmat = w.reshape(kh * kw * cin, cout)            # (KH*KW*Cin, Cout)
    out = matmul(patches, wmat) + b                  # Pallas matmul
    return out.reshape(bs, h, wd, cout)


def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fully connected layer: (B, Din) @ (Din, Dout) + b, on the L1 matmul."""
    return matmul(x, w) + b
