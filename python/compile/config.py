"""Model / artifact shape configurations shared by model.py, aot.py, tests.

Two configs are AOT-compiled:

* ``paper`` -- the paper's CNN: 32x32x3 inputs, conv(3->16,5x5) ->
  conv(16->32,5x5) -> fc(2048->100) -> fc(100->10) = 219,958 parameters
  (paper reports "approximately 225,034"; see DESIGN.md §7).
* ``fast``  -- same architecture on 16x16x3 inputs (66,358 params), used by
  the large experiment sweeps so the full fault grids fit the single-core
  CPU budget of this environment.

All request-path shapes are fixed at lower time (PJRT executables are
static-shape); variable peer count is handled by masking in the aggregate
artifact (weights of absent peers = 0) and variable local-data size by a
fixed number of local minibatches per round (sampled by the rust side).
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class LayerDims:
    """Derived per-layer parameter slicing of the flat vector."""

    name: str
    shape: tuple
    offset: int

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclass(frozen=True)
class ModelConfig:
    name: str
    img: int = 32          # square image edge
    channels: int = 3
    classes: int = 10
    c1: int = 16            # conv1 out channels
    c2: int = 32            # conv2 out channels
    k: int = 5              # conv kernel edge
    hidden: int = 100       # fc1 width
    batch: int = 32         # minibatch size B
    nb_train: int = 8       # minibatches per local round (train_epoch scan)
    nb_eval_round: int = 8  # minibatches for the per-round accuracy probe
    nb_eval_full: int = 32  # minibatches for the final full evaluation
    k_max: int = 16         # max peers in the aggregate artifact

    @property
    def flat_after_pool(self) -> int:
        # two stride-2 2x2 max pools on SAME convs: img -> img/2 -> img/4
        e = self.img // 4
        return e * e * self.c2

    def layers(self) -> list:
        """Flat-vector layout: [w1, b1, w2, b2, w3, b3, w4, b4]."""
        dims = [
            ("conv1_w", (self.k, self.k, self.channels, self.c1)),
            ("conv1_b", (self.c1,)),
            ("conv2_w", (self.k, self.k, self.c1, self.c2)),
            ("conv2_b", (self.c2,)),
            ("fc1_w", (self.flat_after_pool, self.hidden)),
            ("fc1_b", (self.hidden,)),
            ("fc2_w", (self.hidden, self.classes)),
            ("fc2_b", (self.classes,)),
        ]
        out, off = [], 0
        for name, shape in dims:
            ld = LayerDims(name, shape, off)
            out.append(ld)
            off += ld.size
        return out

    @property
    def n_params(self) -> int:
        return sum(l.size for l in self.layers())


PAPER = ModelConfig(name="paper", img=32, nb_train=8)
FAST = ModelConfig(name="fast", img=16, nb_train=4)
# `tiny` keeps the full 36-run fault grids affordable on one CPU core.
TINY = ModelConfig(
    name="tiny",
    img=8,
    c1=8,
    c2=16,
    k=3,
    hidden=64,
    batch=16,
    nb_train=6,
    nb_eval_round=8,
    nb_eval_full=32,
)

CONFIGS = {"paper": PAPER, "fast": FAST, "tiny": TINY}
