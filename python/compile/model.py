"""Layer-2 JAX model: the paper's CNN, train/eval/aggregate compute graphs.

Everything here is build-time Python: `aot.py` lowers these jitted functions
once to HLO text; the rust coordinator executes the artifacts via PJRT and
never imports this module.

The architecture follows SS4 of the paper: two SAME 5x5 convolutions with
2x2 max pooling, then two fully-connected layers; ~220k parameters at 32x32
(paper: "approximately 225,034").  All FLOP-heavy contractions (conv fwd/bwd,
dense fwd/bwd, SGD update, FedAvg aggregation) run through the L1 Pallas
kernels in ``kernels/``.

Parameters travel as ONE flat f32 vector -- that is also the wire format the
rust P2P layer broadcasts, and the representation the Client-Confident
Convergence test measures L2 distance on.
"""

import functools

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import conv2d, dense, fedavg, sgd_update


# --------------------------------------------------------------------------
# Parameter (un)flattening
# --------------------------------------------------------------------------

def unflatten(cfg: ModelConfig, flat: jax.Array) -> dict:
    """Slice the flat (P,) vector into named layer tensors."""
    out = {}
    for layer in cfg.layers():
        out[layer.name] = jax.lax.dynamic_slice(
            flat, (layer.offset,), (layer.size,)
        ).reshape(layer.shape)
    return out


def init_params(cfg: ModelConfig, seed: jax.Array) -> jax.Array:
    """He-init each layer from a u32 seed; returns the flat (P,) vector.

    Deterministic in `seed`, so every client derives the identical model-0
    without any coordination round (the paper assumes a common init).
    """
    key = jax.random.key(seed.astype(jnp.uint32))
    parts = []
    for layer in cfg.layers():
        key, sub = jax.random.split(key)
        if layer.name.endswith("_b"):
            parts.append(jnp.zeros((layer.size,), jnp.float32))
        else:
            fan_in = layer.size // layer.shape[-1]
            std = jnp.sqrt(2.0 / fan_in)
            parts.append(
                jax.random.normal(sub, (layer.size,), jnp.float32) * std
            )
    return jnp.concatenate(parts)


# --------------------------------------------------------------------------
# Forward / loss
# --------------------------------------------------------------------------

def _maxpool2(x: jax.Array) -> jax.Array:
    """2x2 stride-2 max pool via reshape (B, H, W, C) -> (B, H/2, W/2, C)."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def forward(cfg: ModelConfig, flat: jax.Array, x: jax.Array) -> jax.Array:
    """CNN forward: x (B, img, img, 3) -> logits (B, classes)."""
    p = unflatten(cfg, flat)
    h = conv2d(x, p["conv1_w"], p["conv1_b"])
    h = _maxpool2(jax.nn.relu(h))
    h = conv2d(h, p["conv2_w"], p["conv2_b"])
    h = _maxpool2(jax.nn.relu(h))
    h = h.reshape(x.shape[0], -1)
    h = jax.nn.relu(dense(h, p["fc1_w"], p["fc1_b"]))
    return dense(h, p["fc2_w"], p["fc2_b"])


def loss_fn(cfg: ModelConfig, flat: jax.Array, x: jax.Array, y: jax.Array):
    """Mean softmax cross-entropy; y is int32 class labels (B,)."""
    logits = forward(cfg, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1)
    return nll.mean()


# --------------------------------------------------------------------------
# Train / eval / aggregate graphs (the AOT surface)
# --------------------------------------------------------------------------

def train_step(cfg: ModelConfig, flat, x, y, lr):
    """One SGD minibatch step: returns (params', loss)."""
    loss, grads = jax.value_and_grad(lambda f: loss_fn(cfg, f, x, y))(flat)
    return sgd_update(flat, grads, lr), loss


def train_epoch(cfg: ModelConfig, flat, xs, ys, lr):
    """`nb` sequential minibatch steps via lax.scan.

    xs: (nb, B, img, img, 3), ys: (nb, B) i32.  Returns (params', mean_loss).
    Scan (not unroll) keeps the lowered HLO one kernel-body long regardless
    of nb -- see DESIGN.md §6 (L2).
    """

    def body(f, xy):
        x, y = xy
        f2, loss = train_step(cfg, f, x, y, lr)
        return f2, loss

    flat2, losses = jax.lax.scan(body, flat, (xs, ys))
    return flat2, losses.mean()


def evaluate(cfg: ModelConfig, flat, xs, ys):
    """Scan over eval minibatches -> (correct_count i32, mean_loss f32)."""

    def body(carry, xy):
        x, y = xy
        logits = forward(cfg, flat, x)
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        correct = jnp.sum((pred == y.astype(jnp.int32)).astype(jnp.int32))
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1)
        return (carry[0] + correct, carry[1] + nll.mean()), None

    (correct, loss_sum), _ = jax.lax.scan(
        body, (jnp.int32(0), jnp.float32(0.0)), (xs, ys)
    )
    return correct, loss_sum / xs.shape[0]


def aggregate(cfg: ModelConfig, stack, weights):
    """Masked FedAvg over the K_MAX x P stack (L1 fedavg kernel)."""
    return fedavg(stack, weights)


# --------------------------------------------------------------------------
# Jitted entry points (shape-specialized per config)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def jitted(cfg: ModelConfig) -> dict:
    """Shape-specialized jitted callables for `cfg` (used by tests + aot)."""
    return {
        "init": jax.jit(lambda seed: (init_params(cfg, seed),)),
        "train_step": jax.jit(
            lambda f, x, y, lr: train_step(cfg, f, x, y, lr),
            donate_argnums=(0,),
        ),
        "train_epoch": jax.jit(
            lambda f, xs, ys, lr: train_epoch(cfg, f, xs, ys, lr),
            donate_argnums=(0,),
        ),
        "evaluate": jax.jit(lambda f, xs, ys: evaluate(cfg, f, xs, ys)),
        "aggregate": jax.jit(lambda s, w: (aggregate(cfg, s, w),)),
    }
